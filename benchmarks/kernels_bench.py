"""Kernel microbenchmarks: CPU-host timings of the reference execution paths.

Pallas kernels target TPU; here we time the chunked jnp twins (the CPU
dispatch path in ``kernels.ops``) and report achieved FLOP/s plus the
modeled TPU roofline occupancy of the kernel working sets (VMEM fit).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.flash_attention import vmem_bytes


def _time(f, *args, iters: int = 3) -> float:
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters


def run(verbose: bool = True) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    key = jax.random.PRNGKey(0)

    # flash attention (B, S, H, D)
    B, S, H, KVH, D = 1, 1024, 8, 4, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KVH, D), jnp.float32)
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True))
    dt = _time(fa, q, k, v)
    flops = 4.0 * B * H * S * S * D / 2  # causal half
    rows.append(("kernels/flash_attn_ms", round(dt * 1e3, 2),
                 f"{flops / dt / 1e9:.1f} GFLOP/s cpu-ref"))
    rows.append(("kernels/flash_attn_vmem_kb",
                 round(vmem_bytes(128, 128, D) / 1024, 1),
                 "128x128 block working set"))

    # ssd scan
    Bs, L, nh, P, N = 1, 2048, 8, 64, 64
    x = jax.random.normal(key, (Bs, L, nh, P), jnp.float32)
    dtt = jax.nn.softplus(jax.random.normal(key, (Bs, L, nh)))
    a_log = jnp.ones((nh,))
    b = jax.random.normal(key, (Bs, L, 1, N)) * 0.3
    c = jax.random.normal(key, (Bs, L, 1, N)) * 0.3
    dsk = jnp.ones((nh,))
    ssd = jax.jit(lambda *a: ops.ssd_scan(*a, chunk=256))
    dt = _time(ssd, x, dtt, a_log, b, c, dsk)
    rows.append(("kernels/ssd_scan_ms", round(dt * 1e3, 2),
                 f"L={L} chunked cpu-ref"))

    # grouped matmul
    E, C, d, f = 8, 256, 512, 1024
    xg = jax.random.normal(key, (E, C, d), jnp.bfloat16)
    wg = jax.random.normal(key, (E, d, f), jnp.bfloat16)
    gm = jax.jit(ops.gmm)
    dt = _time(gm, xg, wg)
    gf = 2.0 * E * C * d * f
    rows.append(("kernels/gmm_ms", round(dt * 1e3, 2),
                 f"{gf / dt / 1e9:.1f} GFLOP/s cpu-ref"))
    if verbose:
        for r in rows:
            print(f"{r[0]:34s} {r[1]:>10} ({r[2]})")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
