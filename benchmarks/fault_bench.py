"""Fault-recovery benchmark: resume+hedge vs naive-restart (DESIGN.md §10).

Runs the open-loop serving stream under an identical seeded fault stream
(per-pool MTBF crashes, transient task failures, straggler slowdowns —
the draws are keyed by ``(seed, workflow, task, attempt)``, so the
injected faults do not depend on the recovery mode) in two postures:

- **naive**   — ``resume=False`` and hedging off: every failed task
  restarts from scratch, stragglers drag to completion.
- **recover** — checkpoint/resume from ``items_done`` plus first-wins
  hedged duplicates for detected stragglers (the PR 5 machinery driving
  fault recovery).

The acceptance gate (exit 1 on failure) is the ISSUE's headline claim:
at equal fault rate, resume+hedge must **match or beat naive-restart on
priority SLO attainment** and **waste fewer device-seconds**. A fault-free
point rides along to pin that the subsystem costs nothing when off
(its metrics must equal ``serving_bench``'s at the same rate).

CLI::

    PYTHONPATH=src python benchmarks/fault_bench.py              # full
    PYTHONPATH=src python benchmarks/fault_bench.py --fast \\
        --json BENCH_faults.json                                 # CI mode
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import repro.configs.workflow_docingest  # noqa: F401,E402
import repro.configs.workflow_rag  # noqa: F401,E402
import repro.configs.workflow_video  # noqa: F401,E402
from repro.core import FaultProfile, Murakkab  # noqa: E402
from repro.core.arrivals import PoissonArrivals, default_mix  # noqa: E402

SEED = 3
TENANTS = ("priority", "standard", "harvest")

#: The benchmark's fault regime: a crash every few hundred device-group
#: seconds per pool, 2% transient task failures, 3% stragglers at 4x.
PROFILE = FaultProfile(
    seed=17,
    instance_mtbf_s={"v5e": 900.0, "v5p": 1200.0, "v4_harvest": 600.0},
    repair_s=120.0,
    task_fail_p=0.02,
    straggler_p=0.03,
)


def _system() -> Murakkab:
    """The deployment-scale cluster (matches serving_bench)."""
    return Murakkab.tpu_cluster(v5e=256, v5p=64, v4_harvest=128,
                                host_cores=512)


def _point(rate: float, horizon: float, warmup: float, *,
           faults: FaultProfile | None, resume: bool = True):
    return _system().open_loop(
        PoissonArrivals(rate_per_s=rate, mix=default_mix(), seed=SEED),
        horizon_s=horizon, warmup_s=warmup, faults=faults, resume=resume,
        collect_trace=False)


def _mode_metrics(prefix: str, rep) -> dict[str, float]:
    m = {
        f"{prefix}/goodput_rps": round(rep.goodput_rps, 4),
        f"{prefix}/energy_wh": round(rep.energy_wh, 1),
        f"{prefix}/completed": rep.completed,
        f"{prefix}/wasted_dev_s": round(rep.wasted_dev_s, 1),
        f"{prefix}/dead_letters": rep.dead_letters,
        f"{prefix}/faults_injected": rep.faults_injected,
        f"{prefix}/hedges_launched": rep.hedges_launched,
    }
    for cls in TENANTS:
        row = rep.per_class.get(cls)
        if row is not None and row["slo_attainment"] is not None:
            m[f"{prefix}/{cls}_attainment"] = round(
                row["slo_attainment"], 4)
    return m


def run(rate: float, horizon: float, warmup: float,
        verbose: bool = True) -> tuple[dict[str, float], dict, bool]:
    """(metrics, info, gate_ok) for one offered load."""
    naive_profile = dataclasses.replace(PROFILE, hedge=False)
    naive = _point(rate, horizon, warmup, faults=naive_profile,
                   resume=False)
    recover = _point(rate, horizon, warmup, faults=PROFILE)
    clean = _point(rate, horizon, warmup, faults=None)

    metrics = _mode_metrics("naive", naive)
    metrics.update(_mode_metrics("recover", recover))
    metrics.update({
        "clean/goodput_rps": round(clean.goodput_rps, 4),
        "clean/energy_wh": round(clean.energy_wh, 1),
        "clean/completed": clean.completed,
    })
    info = {
        "rate_per_s": rate,
        "arrivals": recover.arrivals,
        "profile": {
            "seed": PROFILE.seed,
            "instance_mtbf_s": dict(PROFILE.instance_mtbf_s),
            "repair_s": PROFILE.repair_s,
            "task_fail_p": PROFILE.task_fail_p,
            "straggler_p": PROFILE.straggler_p,
        },
        "recover": {"crashes": recover.instance_crashes,
                    "task_faults": recover.task_faults,
                    "retries": recover.fault_retries,
                    "hedges_won": recover.hedges_won,
                    "resumed_items": recover.resumed_items,
                    "degrade_replans": recover.degrade_replans},
        "naive": {"crashes": naive.instance_crashes,
                  "task_faults": naive.task_faults,
                  "retries": naive.fault_retries},
    }

    n_att = metrics.get("naive/priority_attainment", -1.0)
    r_att = metrics.get("recover/priority_attainment", -1.0)
    gate_att = r_att >= n_att >= 0.0
    gate_waste = recover.wasted_dev_s < naive.wasted_dev_s
    ok = gate_att and gate_waste

    if verbose:
        hdr = (f"{'mode':>8s} {'completed':>10s} {'goodput':>8s} "
               f"{'pri_att':>8s} {'wasted_dev_s':>13s} {'dead':>5s} "
               f"{'energy_wh':>10s}")
        print(hdr)
        print("-" * len(hdr))
        for name, rep in (("clean", clean), ("naive", naive),
                          ("recover", recover)):
            att = rep.per_class.get("priority", {}).get("slo_attainment")
            print(f"{name:>8s} {rep.completed:>10d} "
                  f"{rep.goodput_rps:>8.3f} "
                  f"{(att if att is not None else -1):>8.3f} "
                  f"{rep.wasted_dev_s:>13.1f} {rep.dead_letters:>5d} "
                  f"{rep.energy_wh:>10.1f}")
        print(f"\nfault stream: {recover.faults_injected} faults "
              f"({recover.instance_crashes} crashes, "
              f"{recover.task_faults} task failures), "
              f"{recover.hedges_launched} hedges "
              f"({recover.hedges_won} won), "
              f"{recover.resumed_items} items resumed")
        print(f"gate: priority attainment {r_att:.4f} "
              f"{'>=' if gate_att else '<'} naive {n_att:.4f}; "
              f"wasted {recover.wasted_dev_s:.1f} "
              f"{'<' if gate_waste else '>='} "
              f"naive {naive.wasted_dev_s:.1f} dev-s "
              f"=> {'PASS' if ok else 'FAIL'}")
    return metrics, info, ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="short horizon (CI bench-smoke mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (e.g. BENCH_faults.json)")
    args = ap.parse_args()

    # rate 1.0/s puts the cluster under enough pressure that stragglers
    # and retries actually cost SLO attainment — the regime where the
    # recovery machinery has something to win back
    if args.fast:
        rate, horizon, warmup = 1.0, 2000.0, 200.0
    else:
        rate, horizon, warmup = 1.0, 8000.0, 800.0

    metrics, info, ok = run(rate, horizon, warmup)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "faults",
                       "mode": "fast" if args.fast else "full",
                       "info": info, "metrics": metrics},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
