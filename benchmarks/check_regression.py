"""Regression gate for the CI ``bench-smoke`` job.

    python benchmarks/check_regression.py \
        --baseline benchmarks/baselines/BENCH_scenarios.json \
        --current BENCH_scenarios.json [--tolerance 0.2]

Compares the ``metrics`` maps of two benchmark JSON files (written by
``scenarios_bench.py --json`` / ``multitenant.py --json``). A metric
regresses when it moves in its *bad* direction by more than ``tolerance``
(relative, default 20%):

- names containing ``quality``, ``saving``, ``warm_hit``, ``hit_rate``,
  ``attainment``, ``goodput`` or ``completed`` are higher-is-better
  (serving: SLO attainment, goodput, workflows drained at fixed offered
  load);
- names containing ``resumed``, ``scale_actions``, ``faults_injected``,
  ``hedges_launched`` or ``weight_churn`` are *neutral*: reported, never
  gated — more
  salvaged work-items usually means more preemptions happened,
  autoscaler activity tracks the policy's tick/cooldown interplay, and
  fault/hedge counts track the seeded fault stream, so neither direction
  is a regression on its own (``wasted_dev_s`` is the gated
  lower-is-better signal for the checkpoint/resume and fault paths,
  energy/attainment for autoscaling);
- everything else (makespan/span/energy/$/preemptions/requeues/
  ``wasted_dev_s``) is lower-is-better.

Engine throughput (``info.events_per_s``, written by ``serving_bench.py``)
is additionally gated as higher-is-better when both files carry it —
under its own, wider ``--throughput-tolerance`` (default 50%), because
wall-clock on a shared runner is noisy where the ``metrics`` map is
deterministic. The gate catches engine-level slowdowns (an accidental
O(n^2) rescan, a dropped memo), not scheduling jitter.

Integer-valued metrics (event counts: preemptions, requeues) get one unit
of absolute slack on top of the relative tolerance — a 1→2 preemption move
is not a 100% regression worth failing CI over; large count jumps still
trip the gate.

Metrics present on only one side are reported but do not fail the gate
(the benchmark grew or was re-keyed — update the baseline in the same PR).
The simulator is deterministic, so baseline drift only comes from real
code changes, never from runner noise.
"""
from __future__ import annotations

import argparse
import json
import sys

HIGHER_IS_BETTER = ("quality", "saving", "warm_hit", "hit_rate",
                    "attainment", "goodput", "completed", "events_per_s")
# reported but never gated: value tracks event counts (e.g. work-items
# salvaged by resume scales with how many preemptions occurred, scale
# actions with the autoscaler's tick/cooldown interplay, injected faults
# and launched hedges with the seeded fault stream, router weight churn
# with the telemetry log's composition), so no direction is inherently
# bad (``wasted_dev_s``/attainment are the gated signals for the fault
# path, energy/$/attainment for the routing loop)
NEUTRAL = ("resumed", "scale_actions", "faults_injected",
           "hedges_launched", "weight_churn")


def better_higher(name: str) -> bool:
    return any(tok in name for tok in HIGHER_IS_BETTER)


def neutral(name: str) -> bool:
    return any(tok in name for tok in NEUTRAL)


def compare(baseline: dict, current: dict, tolerance: float) \
        -> tuple[list[str], list[str]]:
    """Returns (regressions, notes)."""
    regressions, notes = [], []
    for name in sorted(set(baseline) | set(current)):
        if name not in current:
            notes.append(f"missing in current: {name}")
            continue
        if name not in baseline:
            notes.append(f"new metric (no baseline): {name}")
            continue
        base, cur = float(baseline[name]), float(current[name])
        if base == cur:
            continue
        delta = cur - base
        if neutral(name):
            notes.append(f"{name}: {base} -> {cur} (neutral, not gated)")
            continue
        bad = -delta if better_higher(name) else delta
        slack = tolerance * abs(base)
        if base.is_integer() and cur.is_integer():
            slack += 1.0        # event counts: one unit of absolute slack
        rel = delta / max(abs(base), 1e-9)
        line = f"{name}: {base} -> {cur} ({rel:+.1%})"
        if bad > slack:
            regressions.append(line)
        else:
            notes.append(line)
    return regressions, notes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="max relative move in the bad direction (0.2 = 20%)")
    ap.add_argument("--throughput-tolerance", type=float, default=0.5,
                    help="separate (wider) tolerance for the gated "
                         "info.events_per_s engine-throughput metric — "
                         "wall-clock noise on shared runners")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.current) as f:
        cur = json.load(f)
    regressions, notes = compare(base.get("metrics", base),
                                 cur.get("metrics", cur), args.tolerance)
    # engine throughput: gated higher-is-better, own tolerance (wall clock)
    b_ev = base.get("info", {}).get("events_per_s")
    c_ev = cur.get("info", {}).get("events_per_s")
    if b_ev is not None and c_ev is not None:
        r2, n2 = compare({"info/events_per_s": b_ev},
                         {"info/events_per_s": c_ev},
                         args.throughput_tolerance)
        regressions += r2
        notes += n2
    for line in notes:
        print(f"  note: {line}")
    if regressions:
        print(f"{len(regressions)} regression(s) beyond "
              f"{args.tolerance:.0%} vs {args.baseline}:")
        for line in regressions:
            print(f"  REGRESSION {line}")
        return 1
    print(f"ok: {args.current} within {args.tolerance:.0%} of "
          f"{args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
