"""Capture per-batch latency curves for pinned (measured) profile rows.

Single-point pins batch via the deprecated ``batch ** alpha`` scalar
(DESIGN.md §7.1); this helper captures the *batch curve* — per-item latency
at a grid of batch sizes — so a calibration can be pinned with real batch
behaviour and the fallback retires (§7.2)::

    PYTHONPATH=src python -m benchmarks.calibrate_batch_curves \
        --impl gemma2-9b-digest --device tpu-v5e --counts 1 4 \
        --json curves.json

    # later, in a session:
    from benchmarks.calibrate_batch_curves import pin_curves
    pin_curves(system.profiles, json.load(open("curves.json")))

The probe here evaluates the analytic batch roofline at each grid point —
the offline stand-in this repo uses for wall-clock profiling runs (the
same substitution as DESIGN.md §5.4: measured timings would be recorded by
the serving harness on real hardware; the capture/pin plumbing is
identical either way). The batch grid is pow2 up to ``max_batch`` plus the
compute knee's floor/ceil, so the pinned curve brackets the
memory→compute transition and the store's log-log interpolation stays
faithful between points.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                ".."))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.core import CATALOG, Murakkab
from repro.core.energy import batch_knee
from repro.core.profiles import CostQuery, ProfileStore


def batch_grid(impl, spec, tokens_in: int = 1024, tokens_out: int = 256,
               efficiency: float | None = None) -> list[int]:
    """Measurement grid: pow2 through max_batch + the knee's floor/ceil.

    The knee is evaluated at the same token footprint the capture probes,
    so the grid brackets the memory→compute transition of the curve being
    measured.
    """
    eff = impl.mxu_efficiency if efficiency is None else efficiency
    grid = {1}
    b = 2
    while b <= impl.max_batch:
        grid.add(b)
        b *= 2
    grid.add(impl.max_batch)
    work = impl.work_fn(tokens_in, tokens_out)
    if work.has_phases:
        knee = batch_knee(work, spec, 1, eff)
        if math.isfinite(knee):
            for k in (math.floor(knee), math.ceil(knee)):
                if 1 <= k <= impl.max_batch:
                    grid.add(int(k))
    return sorted(grid)


def capture_curve(library, impl_name: str, device: str, n_devices: int,
                  tokens_in: int = 1024, tokens_out: int = 256,
                  batches: list[int] | None = None) -> dict[int, float]:
    """Per-item latency at each grid batch size for (impl, device, count).

    Probes a *pristine* ProfileStore (no pins), so the curve reflects the
    analytic roofline — swap the probe for wall-clock timings on real
    hardware; the returned mapping pins identically either way.
    """
    impl = library.impls[impl_name]
    spec = CATALOG[device]
    store = ProfileStore(library)
    work = impl.work_fn(tokens_in, tokens_out)
    bs = batches or batch_grid(impl, spec, tokens_in, tokens_out)
    return {b: store.step_latency(CostQuery(
        impl=impl, spec=spec, n_devices=n_devices, work=work,
        batch=b)) / b for b in bs}


def pin_curves(store: ProfileStore, curves: dict) -> int:
    """Pin a captured-curves JSON structure; returns rows pinned.

    Structure: ``{impl: {device: {str(n_devices): {str(batch):
    per_item_s}}}}`` — what ``main`` emits.
    """
    rows = 0
    for impl_name, devices in curves.items():
        for device, counts in devices.items():
            for n, curve in counts.items():
                store.pin(impl_name, device, int(n),
                          {int(b): float(v) for b, v in curve.items()})
                rows += 1
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--impl", action="append", required=True,
                    help="implementation name (repeatable)")
    ap.add_argument("--device", default="tpu-v5e")
    ap.add_argument("--counts", type=int, nargs="+", default=[1])
    ap.add_argument("--tokens-in", type=int, default=1024)
    ap.add_argument("--tokens-out", type=int, default=256)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the curves (pin with pin_curves)")
    args = ap.parse_args()

    library = Murakkab.tpu_cluster().library
    out: dict = {}
    for impl_name in args.impl:
        impl = library.impls[impl_name]
        spec = CATALOG[args.device]
        if spec.kind not in impl.hw_kinds:
            print(f"skip {impl_name}: no {spec.kind} support")
            continue
        for n in args.counts:
            curve = capture_curve(library, impl_name, args.device, n,
                                  args.tokens_in, args.tokens_out)
            out.setdefault(impl_name, {}).setdefault(args.device, {})[
                str(n)] = {str(b): v for b, v in curve.items()}
            pts = ", ".join(f"b={b}: {v * 1e3:.2f}ms" for b, v in
                            curve.items())
            print(f"{impl_name} on {n}x {args.device}: {pts}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
