"""Fig. 3 reproduction: execution traces of the Video-Understanding workflow.

Baseline (fixed, sequential) vs the three Murakkab STT configurations.
Emits ASCII traces + the speedup headline (~3.4x).
"""
from __future__ import annotations

from repro.core.simulator import render_trace

from .paper_eval import PAPER_TARGETS, run_all


def run(verbose: bool = True) -> list[tuple[str, float, str]]:
    res = run_all()
    rows: list[tuple[str, float, str]] = []
    for name, (mk, wh, rep) in res.items():
        target = PAPER_TARGETS[name][0]
        rows.append((f"fig3/{name}/makespan_s", round(mk, 1),
                     f"paper={target:.0f}s"))
        if verbose:
            sim = rep.sim if hasattr(rep, "sim") else rep
            print(f"\n=== {name} ===")
            print(render_trace(sim))
    speed = res["baseline"][0] / res["cpu"][0]
    rows.append(("fig3/speedup_x", round(speed, 2), "paper~3.4x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
