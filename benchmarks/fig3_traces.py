"""Fig. 3 reproduction: execution traces of the Video-Understanding workflow.

Baseline (fixed, sequential) vs the three Murakkab STT configurations.
Emits ASCII traces + the speedup headline (~3.4x).

``--trace-limit`` caps each rendered trace at N evenly-subsampled task
rows (``render_trace``'s ``max_rows``); 0 disables the cap. Open-loop
serving runs produce tens of thousands of trace rows — the cap keeps the
ASCII view readable and O(limit) instead of O(events).
"""
from __future__ import annotations

import argparse

from repro.core.simulator import render_trace

from .paper_eval import PAPER_TARGETS, run_all

DEFAULT_TRACE_LIMIT = 200


def run(verbose: bool = True,
        trace_limit: int = DEFAULT_TRACE_LIMIT) -> list[tuple[str, float, str]]:
    res = run_all()
    rows: list[tuple[str, float, str]] = []
    for name, (mk, wh, rep) in res.items():
        target = PAPER_TARGETS[name][0]
        rows.append((f"fig3/{name}/makespan_s", round(mk, 1),
                     f"paper={target:.0f}s"))
        if verbose:
            sim = rep.sim if hasattr(rep, "sim") else rep
            print(f"\n=== {name} ===")
            print(render_trace(sim, max_rows=trace_limit))
    speed = res["baseline"][0] / res["cpu"][0]
    rows.append(("fig3/speedup_x", round(speed, 2), "paper~3.4x"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-limit", type=int, default=DEFAULT_TRACE_LIMIT,
                    help="max task rows per rendered trace, evenly "
                         "subsampled (0 = no cap)")
    args = ap.parse_args()
    for r in run(trace_limit=args.trace_limit):
        print(",".join(map(str, r)))
