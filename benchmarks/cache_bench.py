"""KV/prefix-cache serving benchmark: affinity-aware vs cache-blind (DESIGN.md §9).

Serves an identical seeded multi-turn chat session stream twice through
the open-loop engine — once with session-affinity placement (warm
instances holding the session's KV prefix are preferred and warm prefill
is priced at the cache hit rate) and once cache-blind (``cache_affinity``
off: placement ignores residency, every turn pays cold prefill) — and
reports the speed and energy win of treating cache residency as a
cluster resource. The acceptance check is the PR's headline claim:
affinity must beat blind on **both** p95 turn span **and** energy at
equal-or-better priority-class SLO attainment (exit 1 otherwise).

The chat geometry (``configs/workflow_chat.py``) is a tool-calling
agent's: a fat system prompt and per-turn context with short structured
replies, which keeps turns prefill-compute-bound — the regime where
prefix reuse actually moves the roofline (decode-heavy chat is
weight-bandwidth-bound and a prefill discount is invisible there).

CLI::

    PYTHONPATH=src python benchmarks/cache_bench.py              # full run
    PYTHONPATH=src python benchmarks/cache_bench.py --fast \\
        --json BENCH_cache.json                                  # CI mode
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import repro.configs.workflow_chat  # noqa: F401,E402  (registers preset)
from repro.core import Murakkab  # noqa: E402
from repro.core.arrivals import SERVING_PRESETS, SessionArrivals  # noqa: E402

SEED = 7
WARMUP_S = 300.0


def _system() -> Murakkab:
    """A mid-size slice of the deployment cluster: small enough that chat
    sessions contend for warm instances (residency matters), large enough
    that the blind run is not queue-bound."""
    return Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=32,
                                host_cores=128)


def _stream(rate: float) -> SessionArrivals:
    return SessionArrivals(rate, scenario="chat", mean_turns=6.0,
                           think_time_s=30.0, seed=SEED)


def _run(rate: float, horizon: float, affinity: bool):
    return _system().open_loop(
        _stream(rate), horizon_s=horizon, warmup_s=WARMUP_S,
        presets={"chat": SERVING_PRESETS["chat"]},
        collect_trace=False, cache_affinity=affinity)


def _p95_span(rep) -> float:
    """p95 turn span over workflows arriving past warmup (matches the
    per-class steady-state trim)."""
    spans = sorted(rep.workflow_span(wf)
                   for wf, row in rep.per_workflow.items()
                   if row["start"] >= WARMUP_S and row["finish"] > 0)
    if not spans:
        return 0.0
    return spans[int(0.95 * (len(spans) - 1))]


def comparison(rate: float, horizon: float, verbose: bool = True) \
        -> tuple[dict[str, float], bool]:
    """Affinity vs blind on the identical session stream."""
    warm = _run(rate, horizon, affinity=True)
    cold = _run(rate, horizon, affinity=False)

    wp95, cp95 = _p95_span(warm), _p95_span(cold)
    watt = warm.per_class.get("priority", {}).get("slo_attainment", 0.0)
    catt = cold.per_class.get("priority", {}).get("slo_attainment", 0.0)
    m: dict[str, float] = {
        "affinity/hit_rate": round(warm.cache_hit_rate, 4),
        "affinity/prefill_tokens_saved": round(warm.prefill_tokens_saved),
        "affinity/p95_s": round(wp95, 3),
        "affinity/energy_wh": round(warm.energy_wh, 1),
        "affinity/priority_attainment": round(watt, 4),
        "affinity/completed": warm.completed,
        "blind/hit_rate": round(cold.cache_hit_rate, 4),
        "blind/p95_s": round(cp95, 3),
        "blind/energy_wh": round(cold.energy_wh, 1),
        "blind/priority_attainment": round(catt, 4),
        "cache/p95_saving_x": round(cp95 / max(wp95, 1e-9), 3),
        "cache/energy_saving_x": round(
            cold.energy_wh / max(warm.energy_wh, 1e-9), 4),
    }
    ok = (wp95 < cp95 and warm.energy_wh < cold.energy_wh
          and watt >= catt and warm.cache_hit_rate > cold.cache_hit_rate)
    if verbose:
        print(f"chat sessions @ rate={rate:g}/s x {horizon:g}s "
              f"({warm.arrivals} turns, {warm.completed} completed):")
        print(f"  affinity: hit {warm.cache_hit_rate:.3f}  "
              f"p95 {wp95:.3f}s  energy {warm.energy_wh:.1f} Wh  "
              f"priority att {watt:.3f}")
        print(f"  blind:    hit {cold.cache_hit_rate:.3f}  "
              f"p95 {cp95:.3f}s  energy {cold.energy_wh:.1f} Wh  "
              f"priority att {catt:.3f}")
        print(f"  saving: p95 {m['cache/p95_saving_x']:.2f}x, "
              f"energy {m['cache/energy_saving_x']:.3f}x, "
              f"{m['affinity/prefill_tokens_saved']:.0f} prefill tokens "
              f"un-recomputed")
        print(f"affinity {'beats' if ok else 'does NOT beat'} cache-blind "
              f"placement on p95 AND energy at equal priority attainment")
    return m, ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="short horizon (CI bench-smoke mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (e.g. BENCH_cache.json)")
    args = ap.parse_args()

    if args.fast:
        rate, horizon = 0.2, 1800.0
    else:
        rate, horizon = 0.2, 5400.0

    metrics, ok = comparison(rate, horizon)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "cache",
                       "mode": "fast" if args.fast else "full",
                       "metrics": metrics},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
