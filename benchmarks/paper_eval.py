"""Shared harness for the paper's evaluation (§4): the four configurations.

Builds the baseline (Listing 1: pinned, sequential) and the three Murakkab
STT configurations of Fig. 3 / Table 2:

  - ``cpu``      STT on 64-core Whisper instances (what MIN_COST selects)
  - ``gpu``      STT on 1 A100 (batched decode), "similar to the baseline"
  - ``gpu+cpu``  STT split: 6 scenes on the GPU + 2 scenes on a 64-core pool

The GPU and GPU+CPU rows are *constructed* configurations — the paper shows
them as "execution traces from the various resource configurations that
Murakkab can choose"; only the CPU row is what the MIN_COST constraint
actually selects (asserted in tests).
"""
from __future__ import annotations

from repro.core import MIN_COST, Murakkab
from repro.core.dag import DAG
from repro.core.simulator import Simulator
from repro.configs.workflow_video import (PAPER_VIDEOS,
                                          make_baseline_workflow,
                                          make_declarative_job)

PAPER_TARGETS = {
    "baseline": (285.0, 155.0),
    "cpu": (83.0, 34.0),
    "gpu": (77.0, 43.0),
    "gpu+cpu": (77.0, 42.0),
}


def prewarm(system: Murakkab):
    """The always-on serving capacity of the paper's cluster."""
    system.prewarm("nvlm-72b", "gpu", 8)
    system.prewarm("nvlm-embed", "gpu", 2)
    system.prewarm("whisper-large", "gpu", 1)


def run_baseline():
    system = Murakkab.paper_cluster()
    wf = make_baseline_workflow()
    return wf.execute(system, inputs=PAPER_VIDEOS)


def run_murakkab_cpu():
    """The config MIN_COST actually picks (STT on CPU cores)."""
    system = Murakkab.paper_cluster()
    prewarm(system)
    return make_declarative_job(MIN_COST).execute(system)


def _murakkab_dag(system: Murakkab):
    job = make_declarative_job(MIN_COST)
    dag = system.lower(job)
    plan = system.scheduler.plan(dag, job.constraint_order,
                                 job.quality_floor)
    return job, dag, plan


def run_murakkab_gpu():
    """STT forced onto 1 A100 (batched): the paper's 'GPU' row."""
    system = Murakkab.paper_cluster()
    prewarm(system)
    _, dag, plan = _murakkab_dag(system)
    stt_id = next(t for t in dag.topo_order if "speech" in t)
    pinned = system.scheduler.pin(dag.nodes[stt_id], "whisper-large",
                                  "gpu", 1)
    plan.configs[stt_id] = pinned.with_(batch=2, warm=True)
    sim = Simulator(system.cluster, system.library, system.profiles)
    return sim.run({"gpu": (dag, plan, 0.0)})


def run_murakkab_gpu_cpu():
    """STT split 6 GPU-scenes + 2 CPU-scenes: the paper's 'GPU + CPU' row."""
    system = Murakkab.paper_cluster()
    prewarm(system)
    _, dag, plan = _murakkab_dag(system)
    stt_id = next(t for t in dag.topo_order if "speech" in t)
    old = dag.nodes[stt_id]
    # split the STT node across the two pools
    gpu_node = old.with_(id=stt_id + "_gpu", work_items=6)
    cpu_node = old.with_(id=stt_id + "_cpu", work_items=2)
    nodes = []
    for tid in dag.topo_order:
        n = dag.nodes[tid]
        if tid == stt_id:
            nodes += [gpu_node, cpu_node]
        elif stt_id in n.deps:
            nodes.append(n.with_(deps=tuple(
                d for d in n.deps if d != stt_id) +
                (gpu_node.id, cpu_node.id)))
        else:
            nodes.append(n)
    dag2 = DAG(nodes)
    plan.configs[gpu_node.id] = system.scheduler.pin(
        gpu_node, "whisper-large", "gpu", 1).with_(warm=True)
    plan.configs[cpu_node.id] = system.scheduler.pin(
        cpu_node, "whisper-large", "cpu", 64)
    del plan.configs[stt_id]
    sim = Simulator(system.cluster, system.library, system.profiles)
    return sim.run({"gpu+cpu": (dag2, plan, 0.0)})


def run_all() -> dict[str, tuple[float, float, object]]:
    """{config: (makespan_s, energy_wh, report-ish)} for all four rows."""
    base = run_baseline()
    cpu = run_murakkab_cpu()
    gpu = run_murakkab_gpu()
    mix = run_murakkab_gpu_cpu()
    return {
        "baseline": (base.makespan_s, base.energy_wh, base),
        "cpu": (cpu.makespan_s, cpu.energy_wh, cpu),
        "gpu": (gpu.makespan_s, gpu.energy_wh, gpu),
        "gpu+cpu": (mix.makespan_s, mix.energy_wh, mix),
    }
