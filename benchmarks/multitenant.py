"""Fig. 2 vision: multi-tenant multiplexing on the shared TPU cluster.

N independent video-understanding workflows arrive staggered. Murakkab's
shared scheduling (warm-instance reuse + workflow-aware rebalance) is
compared against the siloed status quo (each tenant gets a dedicated
cluster slice, models cold per tenant).

Metrics: total makespan, energy, warm-hit ratio, pool utilization.
"""
from __future__ import annotations

from repro.core import MIN_LATENCY, Murakkab
from repro.core.workflow import Job, VideoInput


def _job(i: int) -> Job:
    return Job(
        description=f"List objects shown/mentioned in tenant {i}'s videos",
        inputs=(VideoInput(f"tenant{i}.mov", scenes=4, frames_per_scene=10),),
        constraints=MIN_LATENCY, quality_floor=0.8)


def run(verbose: bool = True, n_tenants: int = 8,
        stagger_s: float = 2.0) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []

    # shared Murakkab cluster
    shared = Murakkab.tpu_cluster(v5e=64, v5p=0, v4_harvest=0, host_cores=256)
    report = shared.execute_many(
        {f"wf{i}": (_job(i), i * stagger_s) for i in range(n_tenants)})
    warm_hits = sum(1 for e in report.trace if e.note == "warm")
    starts = sum(1 for e in report.trace if e.note in ("warm", "cold"))
    rows.append(("multitenant/shared_makespan_s", round(report.makespan_s, 1),
                 f"{n_tenants} tenants"))
    rows.append(("multitenant/shared_energy_wh", round(report.energy_wh, 1),
                 ""))
    rows.append(("multitenant/warm_hit_ratio",
                 round(warm_hits / max(starts, 1), 3), "instance reuse"))

    # siloed: each tenant keeps a dedicated 1/N slice provisioned for the
    # whole period (the fragmentation the paper calls out) + cold models.
    from repro.core import CATALOG
    silo_span, silo_active = 0.0, 0.0
    chips = max(64 // n_tenants, 8)
    for i in range(n_tenants):
        silo = Murakkab.tpu_cluster(v5e=chips, v5p=0, v4_harvest=0,
                                    host_cores=max(256 // n_tenants, 16))
        r = silo.execute(_job(i))
        silo_span = max(silo_span, i * stagger_s + r.makespan_s)
        silo_active += r.sim.active_wh
    # idle floor: every silo's chips, provisioned over the full span
    idle_wh = n_tenants * chips * CATALOG["tpu-v5e"].idle_w * silo_span / 3600
    silo_energy = silo_active + idle_wh
    rows.append(("multitenant/siloed_makespan_s", round(silo_span, 1), ""))
    rows.append(("multitenant/siloed_energy_wh", round(silo_energy, 1),
                 "slices provisioned for full span"))
    rows.append(("multitenant/energy_saving_x",
                 round(silo_energy / max(report.energy_wh, 1e-9), 2),
                 "shared vs siloed"))
    rows.append(("multitenant/makespan_saving_x",
                 round(silo_span / max(report.makespan_s, 1e-9), 2), ""))
    if verbose:
        for r in rows:
            print(f"{r[0]:38s} {r[1]:>10} ({r[2]})")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
