"""Fig. 2 vision: multi-tenant multiplexing on the shared TPU cluster.

Two experiments:

1. ``run()`` — shared Murakkab cluster vs the siloed status quo (each
   tenant a dedicated slice, models cold per tenant): makespan, energy,
   warm-hit ratio (the original PR-1 benchmark, kept as-is).
2. ``sweep()`` — the adaptive multi-tenant runtime: a mixed
   video + RAG + doc-ingest workload across ``priority``/``standard``/
   ``harvest`` tenant classes, swept over admission policies
   (``fcfs`` / ``strict-priority`` / ``weighted-fair``). Reports per-class
   p50/p95 workflow span, energy, preemption/requeue counts, and the
   checkpoint/resume metrics (``wasted_dev_s`` — executed-then-discarded
   device-seconds, lower is better; ``resumed_items`` — work-items
   salvaged across preemptions); emits ``BENCH_multitenant.json`` for the
   CI ``bench-smoke`` regression gate.

The ``--policy`` acceptance mode additionally replays the featured policy
with checkpoint/resume disabled (``resume=False``, the restart-from-
scratch baseline) and requires resume to cut ``wasted_dev_s`` without
moving the priority-class p95 span.

CLI::

    PYTHONPATH=src python benchmarks/multitenant.py                 # sweep all
    PYTHONPATH=src python benchmarks/multitenant.py --policy strict-priority
    PYTHONPATH=src python benchmarks/multitenant.py --fast --json BENCH_multitenant.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.core import MIN_LATENCY, Murakkab
from repro.core.workflow import Job, VideoInput


def _job(i: int) -> Job:
    return Job(
        description=f"List objects shown/mentioned in tenant {i}'s videos",
        inputs=(VideoInput(f"tenant{i}.mov", scenes=4, frames_per_scene=10),),
        constraints=MIN_LATENCY, quality_floor=0.8)


def run(verbose: bool = True, n_tenants: int = 8,
        stagger_s: float = 2.0) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []

    # shared Murakkab cluster
    shared = Murakkab.tpu_cluster(v5e=64, v5p=0, v4_harvest=0, host_cores=256)
    report = shared.execute_many(
        {f"wf{i}": (_job(i), i * stagger_s) for i in range(n_tenants)})
    warm_hits = sum(1 for e in report.trace if e.note == "warm")
    starts = sum(1 for e in report.trace if e.note in ("warm", "cold"))
    rows.append(("multitenant/shared_makespan_s", round(report.makespan_s, 1),
                 f"{n_tenants} tenants"))
    rows.append(("multitenant/shared_energy_wh", round(report.energy_wh, 1),
                 ""))
    rows.append(("multitenant/warm_hit_ratio",
                 round(warm_hits / max(starts, 1), 3), "instance reuse"))

    # siloed: each tenant keeps a dedicated 1/N slice provisioned for the
    # whole period (the fragmentation the paper calls out) + cold models.
    from repro.core import CATALOG
    silo_span, silo_active = 0.0, 0.0
    chips = max(64 // n_tenants, 8)
    for i in range(n_tenants):
        silo = Murakkab.tpu_cluster(v5e=chips, v5p=0, v4_harvest=0,
                                    host_cores=max(256 // n_tenants, 16))
        r = silo.execute(_job(i))
        silo_span = max(silo_span, i * stagger_s + r.makespan_s)
        silo_active += r.sim.active_wh
    # idle floor: every silo's chips, provisioned over the full span
    idle_wh = n_tenants * chips * CATALOG["tpu-v5e"].idle_w * silo_span / 3600
    silo_energy = silo_active + idle_wh
    rows.append(("multitenant/siloed_makespan_s", round(silo_span, 1), ""))
    rows.append(("multitenant/siloed_energy_wh", round(silo_energy, 1),
                 "slices provisioned for full span"))
    rows.append(("multitenant/energy_saving_x",
                 round(silo_energy / max(report.energy_wh, 1e-9), 2),
                 "shared vs siloed"))
    rows.append(("multitenant/makespan_saving_x",
                 round(silo_span / max(report.makespan_s, 1e-9), 2), ""))
    if verbose:
        for r in rows:
            print(f"{r[0]:38s} {r[1]:>10} ({r[2]})")

    # adaptive runtime: policy sweep in fast mode, surfaced as CSV rows too
    metrics = sweep(verbose=verbose, fast=True)
    for name, value in sorted(metrics.items()):
        rows.append((f"multitenant/{name}", value, "policy sweep (fast)"))
    return rows


# ---------------------------------------------------------------------------
# Adaptive multi-tenant runtime: policy x tenant-mix sweep
# ---------------------------------------------------------------------------

TENANT_CYCLE = ("priority", "standard", "harvest")
POLICY_NAMES = ("fcfs", "strict-priority", "weighted-fair")


def _default_tenants(fast: bool) -> int:
    """One knob for both the sweep and the --policy acceptance run, so the
    gated BENCH json and the acceptance check see the same workload."""
    return 6 if fast else 12


def _pct(xs: list[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 1])."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    k = (len(xs) - 1) * q
    f, c = math.floor(k), math.ceil(k)
    if f == c:
        return xs[f]
    return xs[f] + (xs[c] - xs[f]) * (k - f)


def mixed_jobs(n_tenants: int, stagger_s: float) \
        -> dict[str, tuple[Job, float]]:
    """A mixed video + RAG + doc-ingest workload across tenant classes.

    Scenario and tenant class cycle independently (stride-3 over scenarios,
    stride-1 over classes), so every class runs every workflow shape.
    """
    from repro.configs.workflow_docingest import make_docingest_job
    from repro.configs.workflow_rag import make_rag_job
    from repro.configs.workflow_video import make_declarative_job

    factories = (make_declarative_job, make_rag_job, make_docingest_job)
    jobs: dict[str, tuple[Job, float]] = {}
    for i in range(n_tenants):
        tenant = TENANT_CYCLE[i % len(TENANT_CYCLE)]
        job = factories[(i // len(TENANT_CYCLE)) % len(factories)](
            MIN_LATENCY)
        job = dataclasses.replace(job, tenant_class=tenant,
                                  quality_floor=0.8)
        jobs[f"t{i:02d}_{tenant}"] = (job, i * stagger_s)
    return jobs


def _cluster() -> Murakkab:
    # small enough that tenants contend for the accelerator pool (which is
    # what makes admission policy and preemption visible)
    return Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0, host_cores=96)


def run_policy(policy: str, n_tenants: int = 9, stagger_s: float = 2.0,
               resume: bool = True):
    """One policy over the mixed workload; returns (SimReport, spans).

    ``resume=False`` disables work-item checkpoint/resume — the
    restart-from-scratch baseline the acceptance mode compares against.
    """
    system = _cluster()
    report = system.execute_many(mixed_jobs(n_tenants, stagger_s),
                                 policy=policy, resume=resume)
    spans: dict[str, list[float]] = {c: [] for c in TENANT_CYCLE}
    for wid, row in report.per_workflow.items():
        spans[row["tenant"]].append(report.workflow_span(wid))
    return report, spans


def sweep(verbose: bool = True, fast: bool = False,
          n_tenants: int | None = None, stagger_s: float = 2.0) \
        -> dict[str, float]:
    """Sweep admission policies over the mixed tenant workload."""
    n = n_tenants if n_tenants is not None else _default_tenants(fast)
    metrics: dict[str, float] = {}
    if verbose:
        hdr = (f"{'policy':<16s} {'class':<9s} {'p50_s':>8s} {'p95_s':>8s} "
               f"{'energy_wh':>10s} {'preempt':>8s} {'requeue':>8s} "
               f"{'wasted':>8s} {'resumed':>8s}")
        print(hdr)
        print("-" * len(hdr))
    for policy in POLICY_NAMES:
        report, spans = run_policy(policy, n_tenants=n, stagger_s=stagger_s)
        metrics[f"{policy}/energy_wh"] = round(report.energy_wh, 1)
        metrics[f"{policy}/makespan_s"] = round(report.makespan_s, 1)
        metrics[f"{policy}/preemptions"] = report.preemptions
        metrics[f"{policy}/requeues"] = report.requeues
        metrics[f"{policy}/wasted_dev_s"] = round(report.wasted_dev_s, 2)
        metrics[f"{policy}/resumed_items"] = report.resumed_items
        for cls in TENANT_CYCLE:
            p50 = round(_pct(spans[cls], 0.50), 1)
            p95 = round(_pct(spans[cls], 0.95), 1)
            metrics[f"{policy}/{cls}_p50_s"] = p50
            metrics[f"{policy}/{cls}_p95_s"] = p95
            if verbose:
                print(f"{policy:<16s} {cls:<9s} {p50:>8.1f} {p95:>8.1f} "
                      f"{report.energy_wh:>10.1f} "
                      f"{report.preemptions:>8d} {report.requeues:>8d} "
                      f"{report.wasted_dev_s:>8.2f} "
                      f"{report.resumed_items:>8d}")
    return metrics


def _write_json(path: str, mode: str, metrics: dict[str, float]):
    with open(path, "w") as f:
        json.dump({"bench": "multitenant", "mode": mode,
                   "metrics": metrics}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


def main() -> int:
    featured = [p for p in POLICY_NAMES if p != "fcfs"]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--policy", choices=featured, default=None,
                    help="feature one policy against the fcfs baseline "
                         "(exit 1 unless priority p95 improves); fcfs is "
                         "the baseline itself — omit --policy to sweep it")
    ap.add_argument("--fast", action="store_true",
                    help="smaller tenant mix (CI bench-smoke mode)")
    ap.add_argument("--tenants", type=int, default=None)
    ap.add_argument("--stagger", type=float, default=2.0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (e.g. BENCH_multitenant.json)")
    args = ap.parse_args()
    mode = "fast" if args.fast else "full"

    if args.policy:
        n = args.tenants if args.tenants is not None \
            else _default_tenants(args.fast)
        rep, spans = run_policy(args.policy, n_tenants=n,
                                stagger_s=args.stagger)
        base, base_spans = run_policy("fcfs", n_tenants=n,
                                      stagger_s=args.stagger)
        # restart-from-scratch baseline: same policy, checkpoint/resume off
        restart, restart_spans = run_policy(args.policy, n_tenants=n,
                                            stagger_s=args.stagger,
                                            resume=False)
        print(f"mixed video+RAG+doc-ingest workload, {n} tenants, "
              f"stagger {args.stagger:.0f}s")
        metrics: dict[str, float] = {}
        for policy, r, sp in ((args.policy, rep, spans),
                              ("fcfs", base, base_spans)):
            metrics[f"{policy}/preemptions"] = r.preemptions
            metrics[f"{policy}/requeues"] = r.requeues
            metrics[f"{policy}/wasted_dev_s"] = round(r.wasted_dev_s, 2)
            metrics[f"{policy}/resumed_items"] = r.resumed_items
            for cls in TENANT_CYCLE:
                metrics[f"{policy}/{cls}_p95_s"] = \
                    round(_pct(sp[cls], 0.95), 1)
        for cls in TENANT_CYCLE:
            p95, b95 = _pct(spans[cls], 0.95), _pct(base_spans[cls], 0.95)
            print(f"  {cls:<9s} p95 {args.policy}: {p95:8.1f}s   "
                  f"fcfs: {b95:8.1f}s   ({b95 / max(p95, 1e-9):.2f}x)")
        print(f"  preemptions={rep.preemptions} requeues={rep.requeues} "
              f"(fcfs: {base.preemptions}/{base.requeues})")
        pre = [e for e in rep.trace
               if e.note == "preempted"
               or e.note.split("+")[0] in ("resume", "requeue")]
        for e in pre[:12]:
            print(f"    {e.note:<12s} {e.workflow}:{e.task} "
                  f"[{e.start:8.1f}, {e.end:8.1f}] {e.devices}x{e.pool}")
        if args.json:
            _write_json(args.json, mode, metrics)
        p95, b95 = _pct(spans["priority"], 0.95), \
            _pct(base_spans["priority"], 0.95)
        ok = p95 < b95
        print(f"priority p95 {'improved' if ok else 'NOT improved'} vs fcfs")
        # checkpoint/resume acceptance: preempted harvest work is salvaged
        # (wasted_dev_s drops vs restart-from-scratch) without touching the
        # priority class's p95 span
        h95 = _pct(spans["harvest"], 0.95)
        h95_restart = _pct(restart_spans["harvest"], 0.95)
        p95_restart = _pct(restart_spans["priority"], 0.95)
        print(f"  resume-vs-restart: wasted_dev_s "
              f"{rep.wasted_dev_s:.2f} vs {restart.wasted_dev_s:.2f}, "
              f"resumed_items={rep.resumed_items}, harvest p95 "
              f"{h95:.1f}s vs {h95_restart:.1f}s, priority p95 "
              f"{p95:.1f}s vs {p95_restart:.1f}s")
        # never worse on waste, and the priority class must be untouched
        # (identical up to a relative hair — the sim is deterministic).
        # The *strict* drop is required only when resume actually salvaged
        # items: a preemption that lands mid-weights-load or on a
        # non-chunkable task checkpoints nothing, and demanding a strict
        # win there would fail spuriously on workloads with nothing to save
        resume_ok = (rep.wasted_dev_s <= restart.wasted_dev_s + 1e-9
                     and abs(p95 - p95_restart) <= 1e-6 * max(p95_restart,
                                                              1.0))
        if rep.resumed_items:
            resume_ok = resume_ok and rep.wasted_dev_s \
                < restart.wasted_dev_s - 1e-9
        print(f"checkpoint/resume {'cuts' if resume_ok else 'does NOT cut'}"
              f" wasted work at identical priority p95")
        return 0 if ok and resume_ok else 1

    metrics = sweep(verbose=True, fast=args.fast, n_tenants=args.tenants,
                    stagger_s=args.stagger)
    if args.json:
        _write_json(args.json, mode, metrics)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
