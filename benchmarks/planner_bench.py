"""Planner micro-benchmark: the cost of admission-time planning.

PR 2 moved planning to admission time, so every workflow arriving into
``execute_many`` pays a greedy search against live cluster state. This
bench measures that search over the multi-tenant tenant mix (the same DAG
shapes ``benchmarks/multitenant.py`` admits) and quantifies the three
planner caches (DESIGN.md §7):

- ``baseline`` mode reproduces the pre-cache planner: dominated-config
  pruning off, the ProfileStore estimate memo off, the admission plan
  cache off — every plan re-runs the full greedy search.
- ``fast`` mode turns all three on and replays the admission loop:
  repeated arrivals of the tenant mix into an unchanged cluster, the case
  the plan cache exists for.

Both modes plan the identical workload on identical pristine clusters, so
the bench also *asserts* plan equality config-by-config — the speedup is
at unchanged plan quality by construction.

The knee sweep evaluates the batch roofline for each scenario's
representative decode-bound stage (``BATCH_KNEE_REFERENCE``): per-item
latency vs batch size shows the weights-streaming regime, the
memory→compute knee, and the flat compute-bound tail.

The joint-vs-sequential comparison (DESIGN.md §7.2) plans every scenario
under both lever orders — the joint (count x batch) level-2 search vs the
legacy sequential hierarchy (count at batch=1, then one batch candidate) —
and simulates both plans: the joint search must produce workflow spans <=
the sequential ones on every scenario, strictly better on the
remainder-heavy case (70 chunks against a 64-item max batch leave a
below-knee remainder step the joint divisor grid avoids).

CLI::

    PYTHONPATH=src python benchmarks/planner_bench.py                # full
    PYTHONPATH=src python benchmarks/planner_bench.py --fast \
        --json BENCH_planner.json --min-speedup 5                    # CI

Wall-clock numbers (plans/sec, speedup) go to the JSON ``info`` map —
runner-dependent, not regression-gated. The ``metrics`` map holds only
deterministic quantities (evals/plan, cache hit rates, knee positions).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                ".."))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.core import CATALOG, Murakkab, batch_knee, batch_roofline_latency

from benchmarks.multitenant import mixed_jobs

KNEE_DEVICE = "tpu-v5e"


def _cluster() -> Murakkab:
    # mirrors benchmarks/multitenant.py's contended accelerator pool
    return Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0, host_cores=96)


def _workload(n_tenants: int):
    """The admitted tenant mix as (wid, dag, job) rows, lowered once."""
    system = _cluster()
    jobs = mixed_jobs(n_tenants, stagger_s=2.0)
    return [(wid, system.lower(job), job)
            for wid, (job, _arrival) in sorted(jobs.items())]


def run_mode(fast: bool, n_tenants: int, repeats: int):
    """Plan the tenant mix ``repeats`` times; returns (plans, stats)."""
    system = _cluster()
    system.scheduler.prune = fast
    system.profiles.cache_reset(enabled=fast)
    system.plan_cache_enabled = fast
    rows = _workload(n_tenants)

    plans = {}
    t0 = time.perf_counter()
    for _ in range(repeats):
        for wid, dag, job in rows:
            plans[wid] = system.plan_admitted(dag, job)
    wall_s = time.perf_counter() - t0

    n_plans = repeats * len(rows)
    stats = {
        "wall_s": wall_s,
        "plans": n_plans,
        "plans_per_sec": n_plans / wall_s if wall_s else float("inf"),
        "evals_per_plan": system.scheduler.evals / n_plans,
        "pruned_per_plan": system.scheduler.pruned / n_plans,
        "estimate_cache_hit_rate": system.profiles.cache_info()["hit_rate"],
        "plan_cache_hit_rate": system.plan_cache_hits
        / max(system.plan_cache_hits + system.plan_cache_misses, 1),
    }
    return plans, stats


def joint_vs_sequential(verbose: bool = True) \
        -> tuple[dict[str, float], list[str]]:
    """Workflow spans under the joint vs sequential lever search.

    Each case plans + simulates one workflow on a pristine contended-size
    cluster under ``MIN_LATENCY`` (tail latency is where the remainder
    step shows). Returns deterministic span metrics and a list of
    violations (joint span worse than sequential, or no strict win on the
    remainder-heavy case).
    """
    from repro.core import MIN_LATENCY
    from repro.core.workflow import DocumentInput
    from repro.configs.workflow_docingest import make_docingest_job
    from repro.configs.workflow_rag import make_rag_job
    from repro.configs.workflow_video import make_declarative_job

    cases = {
        "video": (make_declarative_job, {}),
        "rag": (make_rag_job, {}),
        "docingest": (make_docingest_job, {}),
        # 70 chunks vs the digest tier's 64-item max batch: the sequential
        # order charges a 6-item below-knee remainder step that the joint
        # grid's zero-remainder divisor schedule (b=35) avoids
        "docingest_remainder": (make_docingest_job, {
            "documents": (DocumentInput("remainder.pdf", pages=14,
                                        chunks_per_page=5),)}),
    }
    metrics: dict[str, float] = {}
    failures: list[str] = []
    if verbose:
        print("\njoint vs sequential lever search (MIN_LATENCY spans):")
    for name, (make_job, kw) in cases.items():
        spans = {}
        for mode, joint in (("joint", True), ("seq", False)):
            system = _cluster()
            system.scheduler.joint_batch = joint
            spans[mode] = make_job(MIN_LATENCY, **kw).execute(system) \
                .makespan_s
        metrics[f"joint/{name}_span_s"] = round(spans["joint"], 3)
        metrics[f"joint/{name}_seq_span_s"] = round(spans["seq"], 3)
        if spans["joint"] > spans["seq"] * (1 + 1e-9):
            failures.append(
                f"{name}: joint span {spans['joint']:.3f}s exceeds "
                f"sequential {spans['seq']:.3f}s")
        if verbose:
            print(f"  {name:<20s} joint {spans['joint']:8.3f}s   "
                  f"seq {spans['seq']:8.3f}s   "
                  f"shaved {spans['seq'] - spans['joint']:+7.3f}s")
    strict = metrics["joint/docingest_remainder_seq_span_s"] \
        - metrics["joint/docingest_remainder_span_s"]
    if strict <= 0:
        failures.append("no strict win on the remainder-heavy case")
    return metrics, failures


def knee_sweep(verbose: bool = True) -> dict[str, float]:
    """Per-item latency vs batch for each scenario's reference LLM stage."""
    from repro.configs import workflow_docingest, workflow_rag, workflow_video

    refs = {
        "video": workflow_video.BATCH_KNEE_REFERENCE,
        "rag": workflow_rag.BATCH_KNEE_REFERENCE,
        "docingest": workflow_docingest.BATCH_KNEE_REFERENCE,
    }
    spec = CATALOG[KNEE_DEVICE]
    lib = _cluster().library
    metrics: dict[str, float] = {}
    for sname, (impl_name, ti, to) in refs.items():
        impl = lib.impls[impl_name]
        work = impl.work_fn(ti, to)
        knee = batch_knee(work, spec, 1, impl.mxu_efficiency)
        lat1 = batch_roofline_latency(work, spec, 1, 1, impl.mxu_efficiency)
        lat_max = batch_roofline_latency(work, spec, 1, impl.max_batch,
                                         impl.mxu_efficiency)
        metrics[f"knee/{sname}_batch"] = round(knee, 2)
        metrics[f"knee/{sname}_amortization_saving_x"] = \
            round(lat1 / lat_max, 2)
        if verbose:
            print(f"\nknee sweep: {sname} -> {impl_name} "
                  f"({ti}/{to} tok) on {KNEE_DEVICE}, knee b*={knee:.1f}, "
                  f"amortization {lat1 / lat_max:.1f}x")
            curve = []
            b = 1
            while b <= impl.max_batch:
                lat = batch_roofline_latency(work, spec, 1, b,
                                             impl.mxu_efficiency)
                curve.append(f"b={b}: {lat * 1e3:8.2f} ms/item")
                b *= 2
            print("  " + "\n  ".join(curve))
    return metrics


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smaller tenant mix / fewer repeats (CI mode)")
    ap.add_argument("--tenants", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=None,
                    help="admission-loop replays per mode")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (e.g. BENCH_planner.json)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="exit 1 unless fast-path plans/sec beats baseline "
                         "by this factor")
    args = ap.parse_args()
    n = args.tenants if args.tenants is not None else (6 if args.fast else 12)
    repeats = args.repeats if args.repeats is not None \
        else (8 if args.fast else 16)

    base_plans, base = run_mode(fast=False, n_tenants=n, repeats=repeats)
    fast_plans, fast = run_mode(fast=True, n_tenants=n, repeats=repeats)

    # plan quality unchanged: caches + pruning must not move a single config
    mismatched = [wid for wid in base_plans
                  if base_plans[wid].configs != fast_plans[wid].configs]
    if mismatched:
        print(f"PLAN MISMATCH between baseline and fast paths: {mismatched}")
    speedup = fast["plans_per_sec"] / base["plans_per_sec"]

    print(f"planner bench: {n} tenants (mixed video+RAG+doc-ingest), "
          f"{repeats} admission replays per mode")
    hdr = (f"{'mode':<10s} {'plans/s':>10s} {'evals/plan':>11s} "
           f"{'pruned/plan':>12s} {'est-cache':>10s} {'plan-cache':>11s}")
    print(hdr)
    print("-" * len(hdr))
    for name, st in (("baseline", base), ("fast", fast)):
        print(f"{name:<10s} {st['plans_per_sec']:>10.1f} "
              f"{st['evals_per_plan']:>11.1f} {st['pruned_per_plan']:>12.1f} "
              f"{st['estimate_cache_hit_rate']:>10.1%} "
              f"{st['plan_cache_hit_rate']:>11.1%}")
    print(f"speedup: {speedup:.1f}x plans/sec "
          f"({'plan quality unchanged' if not mismatched else 'PLANS DRIFTED'})")

    metrics: dict[str, float] = {
        "evals_per_plan_baseline": round(base["evals_per_plan"], 2),
        "evals_per_plan_fast": round(fast["evals_per_plan"], 2),
        "pruned_per_plan_saving": round(fast["pruned_per_plan"], 2),
        "estimate_cache_hit_rate": round(fast["estimate_cache_hit_rate"], 4),
        "plan_cache_hit_rate": round(fast["plan_cache_hit_rate"], 4),
        "plan_quality_unchanged": 0.0 if mismatched else 1.0,
    }
    metrics.update(knee_sweep())
    joint_metrics, joint_failures = joint_vs_sequential()
    metrics.update(joint_metrics)
    metrics["joint_dominates_sequential"] = \
        0.0 if joint_failures else 1.0
    for f in joint_failures:
        print(f"JOINT-SEARCH FAIL: {f}")
    info = {
        "plans_per_sec_baseline": round(base["plans_per_sec"], 1),
        "plans_per_sec_fast": round(fast["plans_per_sec"], 1),
        "speedup_x": round(speedup, 2),
        "tenants": n, "repeats": repeats,
    }

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "planner",
                       "mode": "fast" if args.fast else "full",
                       "metrics": metrics, "info": info},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")

    if mismatched:
        return 1
    if joint_failures:
        return 1
    if args.min_speedup is not None and speedup < args.min_speedup:
        print(f"FAIL: speedup {speedup:.1f}x < required "
              f"{args.min_speedup:.1f}x")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
