"""Regenerate the §Roofline table inside EXPERIMENTS.md from results/dryrun.

    PYTHONPATH=src:. python -m benchmarks.update_experiments
"""
from __future__ import annotations

import json
import os

from .roofline import RESULTS, analyze, load_records, improvement_hint

MARK_BEGIN = "<!-- ROOFLINE TABLE BEGIN -->"
MARK_END = "<!-- ROOFLINE TABLE END -->"


def full_table() -> str:
    rows = ["", MARK_BEGIN,
            "### §Roofline table — 40 cells, single-pod (data=16, model=16)",
            "",
            "| arch | shape | compute s | memory s | collective s | bound "
            "| MODEL/HLO | roofline frac | what would move the bound |",
            "|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = 0
    for rec in load_records("16x16"):
        if rec.get("serving_rules"):
            continue
        if rec.get("skipped"):
            n_skip += 1
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skip | — | — | {rec['reason']} |")
            continue
        n_ok += 1
        a = analyze(rec)
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3g} | "
            f"{a['t_memory_s']:.3g} | {a['t_collective_s']:.3g} | "
            f"{a['dominant']} | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.3f} | {improvement_hint(a)} |")
    rows.append("")
    rows.append(f"({n_ok} compiled cells + {n_skip} documented skips; "
                "optimized `*_opt` records are reported in §Perf, "
                "not in this baseline table.)")

    # optimized cells comparison
    opt = []
    for name in sorted(os.listdir(RESULTS)):
        if not name.endswith("_opt.json"):
            continue
        with open(os.path.join(RESULTS, name)) as f:
            rec = json.load(f)
        if rec.get("ok"):
            opt.append(rec)
    if opt:
        rows += ["", "### Optimized (serving-rules) cells — §Perf result",
                 "",
                 "| arch | shape | compute s | memory s | collective s | "
                 "bound | roofline frac |",
                 "|---|---|---|---|---|---|---|"]
        for rec in opt:
            a = analyze(rec)
            rows.append(
                f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3g} | "
                f"{a['t_memory_s']:.3g} | {a['t_collective_s']:.3g} | "
                f"{a['dominant']} | {a['roofline_fraction']:.3f} |")
    rows.append(MARK_END)
    return "\n".join(rows)


def main():
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    with open(path) as f:
        txt = f.read()
    if MARK_BEGIN in txt:
        pre = txt.split(MARK_BEGIN)[0].rstrip("\n")
        post = txt.split(MARK_END)[1]
        txt = pre + "\n" + full_table() + post
    else:
        txt = txt.rstrip("\n") + "\n" + full_table() + "\n"
    with open(path, "w") as f:
        f.write(txt)
    print("EXPERIMENTS.md §Roofline table updated")


if __name__ == "__main__":
    main()
