"""Open-loop serving benchmark: SLO attainment vs offered load (DESIGN.md §8).

Sweeps a seeded Poisson arrival stream (mixed video + RAG + doc-ingest
scenarios across priority/standard/harvest tenant classes) over offered
load, reporting per-class SLO attainment, p50/p99 span, goodput, and
energy at each point — the attainment-vs-load curve the paper's serving
story turns on. Two acceptance checks ride along:

1. **Engine throughput** — the largest sweep point re-runs untraced and
   must sustain ``--min-events-per-s`` composite simulator events/s
   (heap events + dispatch attempts, the work the engine actually does).
   The default floor is conservative for shared CI runners; the dev-box
   measurement is recorded in the JSON ``info`` map. Wall-clock numbers
   never go into ``metrics`` (the regression gate only compares
   ``metrics``, which must be deterministic).
2. **Autoscaling** — a target-utilization autoscaler that scales the
   harvest pool to zero while idle must beat the static cluster on
   energy at equal-or-better priority-class SLO attainment on the same
   stream (exit 1 otherwise).

CLI::

    PYTHONPATH=src python benchmarks/serving_bench.py            # full sweep
    PYTHONPATH=src python benchmarks/serving_bench.py --fast \\
        --json BENCH_serving.json                                # CI mode
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import repro.configs.workflow_docingest  # noqa: F401,E402
import repro.configs.workflow_rag  # noqa: F401,E402
import repro.configs.workflow_video  # noqa: F401,E402
from repro.core import FaultProfile, Murakkab  # noqa: E402
from repro.core.arrivals import PoissonArrivals, default_mix  # noqa: E402
from repro.core.autoscale import Autoscaler, PoolPolicy  # noqa: E402

SEED = 3
TENANTS = ("priority", "standard", "harvest")


def _system() -> Murakkab:
    """The deployment-scale cluster (matches the closed-loop benches)."""
    return Murakkab.tpu_cluster(v5e=256, v5p=64, v4_harvest=128,
                                host_cores=512)


def _harvest_autoscaler() -> Autoscaler:
    """Scale-to-zero on the harvest pool; reserved pools stay static
    (warm) — the policy shape ``Autoscaler.validate`` enforces."""
    return Autoscaler({"v4_harvest": PoolPolicy(
        min_devices=0, max_devices=128, target_util=0.75,
        scale_up_lag_s=30.0, cooldown_s=60.0)}, interval_s=15.0)


def _point(rate: float, horizon: float, warmup: float,
           autoscaler: Autoscaler | None = None,
           faults: FaultProfile | None = None):
    return _system().open_loop(
        PoissonArrivals(rate_per_s=rate, mix=default_mix(), seed=SEED),
        horizon_s=horizon, warmup_s=warmup, autoscaler=autoscaler,
        faults=faults, collect_trace=False)


def faults_smoke(rate: float, horizon: float, warmup: float,
                 verbose: bool = True) -> tuple[dict[str, float], bool]:
    """--faults: one sweep point under a default fault profile.

    A serving-path sanity check that fault injection and recovery run end
    to end on this benchmark's cluster/stream (the recovery-vs-naive
    comparison itself lives in ``fault_bench.py``). Fails when no faults
    fire or admitted workflows go missing (neither completed nor
    dead-lettered).
    """
    fp = FaultProfile(seed=17,
                      instance_mtbf_s={"v5e": 900.0, "v5p": 1200.0,
                                       "v4_harvest": 600.0},
                      repair_s=120.0, task_fail_p=0.02, straggler_p=0.03)
    rep = _point(rate, horizon, warmup, faults=fp)
    m = {
        "faults/goodput_rps": round(rep.goodput_rps, 4),
        "faults/energy_wh": round(rep.energy_wh, 1),
        "faults/completed": rep.completed,
        "faults/faults_injected": rep.faults_injected,
        "faults/hedges_launched": rep.hedges_launched,
        "faults/dead_letters": rep.dead_letters,
        "faults/wasted_dev_s": round(rep.wasted_dev_s, 1),
    }
    for cls in TENANTS:
        row = rep.per_class.get(cls)
        if row is not None and row["slo_attainment"] is not None:
            m[f"faults/{cls}_attainment"] = round(row["slo_attainment"], 4)
    ok = rep.faults_injected > 0 and \
        rep.completed + rep.dead_letters == rep.arrivals
    if verbose:
        print(f"\nfaults smoke @ rate={rate:g}/s: "
              f"{rep.faults_injected} faults, "
              f"{rep.hedges_launched} hedges, "
              f"{rep.dead_letters} dead-letters, "
              f"{rep.completed}/{rep.arrivals} completed "
              f"=> {'PASS' if ok else 'FAIL'}")
    return m, ok


def sweep(rates: tuple[float, ...], horizon: float, warmup: float,
          verbose: bool = True) -> tuple[dict[str, float], dict]:
    """Attainment-vs-offered-load curve; returns (metrics, throughput info).

    The largest point doubles as the engine-throughput measurement (its
    wall clock and event counts go to ``info``, not ``metrics``).
    """
    metrics: dict[str, float] = {}
    info: dict = {}
    if verbose:
        hdr = (f"{'rate/s':>7s} {'arrivals':>9s} {'goodput':>8s} "
               + "".join(f" {c + '_att':>12s}" for c in TENANTS)
               + f" {'pri_p99_s':>10s} {'energy_wh':>10s}")
        print(hdr)
        print("-" * len(hdr))
    for rate in rates:
        rep = _point(rate, horizon, warmup)
        key = f"load_r{rate:g}"
        metrics[f"{key}/goodput_rps"] = round(rep.goodput_rps, 4)
        metrics[f"{key}/energy_wh"] = round(rep.energy_wh, 1)
        metrics[f"{key}/completed"] = rep.completed
        for cls in TENANTS:
            row = rep.per_class.get(cls)
            if row is None:
                continue
            att = row["slo_attainment"]
            metrics[f"{key}/{cls}_attainment"] = round(att, 4)
            metrics[f"{key}/{cls}_p99_s"] = round(row["p99_s"], 1)
        if rate == max(rates):
            info = {
                "rate_per_s": rate,
                "arrivals": rep.arrivals,
                "n_events": rep.n_events,
                "n_attempts": rep.n_attempts,
                "wall_s": round(rep.wall_s, 3),
                "events_per_s": round(rep.events_per_s),
            }
        if verbose:
            pri = rep.per_class.get("priority", {})
            print(f"{rate:>7g} {rep.arrivals:>9d} "
                  f"{rep.goodput_rps:>8.3f}"
                  + "".join(
                      f" {metrics.get(f'{key}/{c}_attainment', 0):>12.3f}"
                      for c in TENANTS)
                  + f" {pri.get('p99_s', 0):>10.1f}"
                  f" {rep.energy_wh:>10.1f}")
    return metrics, info


def profile_point(rate: float, horizon: float, warmup: float,
                  top_n: int = 15, verbose: bool = True) -> list[dict]:
    """--profile: cProfile the largest sweep point, top-N by cumulative.

    Pure diagnostics for the engine's hot loop (where do the events/s
    go?): the rows land in the JSON ``info`` block — never ``metrics`` —
    so the regression gate ignores them, like every other wall-clock
    artifact.
    """
    import cProfile
    import pstats

    prof = cProfile.Profile()
    prof.enable()
    _point(rate, horizon, warmup)
    prof.disable()
    stats = pstats.Stats(prof)
    stats.sort_stats("cumulative")
    rows: list[dict] = []
    for func in stats.fcn_list:                    # sorted by cumtime
        cc, nc, tt, ct, _callers = stats.stats[func]
        path, line, name = func
        mod = os.path.basename(path) if os.path.sep in path else path
        rows.append({"func": f"{mod}:{line}({name})", "ncalls": nc,
                     "tottime_s": round(tt, 4), "cumtime_s": round(ct, 4)})
        if len(rows) >= top_n:
            break
    if verbose:
        print(f"\nprofile @ rate={rate:g}/s (top {top_n} by cumulative):")
        print(f"{'cumtime':>9s} {'tottime':>9s} {'ncalls':>10s}  function")
        for r in rows:
            print(f"{r['cumtime_s']:>9.3f} {r['tottime_s']:>9.3f} "
                  f"{r['ncalls']:>10d}  {r['func']}")
    return rows


def autoscale_comparison(rate: float, horizon: float, warmup: float,
                         verbose: bool = True) \
        -> tuple[dict[str, float], bool]:
    """Autoscaled vs static cluster on the identical stream."""
    static = _point(rate, horizon, warmup)
    scaled = _point(rate, horizon, warmup,
                    autoscaler=_harvest_autoscaler())
    m: dict[str, float] = {
        "autoscale/static_energy_wh": round(static.energy_wh, 1),
        "autoscale/scaled_energy_wh": round(scaled.energy_wh, 1),
        "autoscale/energy_saving_x": round(
            static.energy_wh / max(scaled.energy_wh, 1e-9), 3),
        "autoscale/scale_actions": len(scaled.scale_actions),
    }
    ok = True
    for cls in TENANTS:
        s = scaled.per_class.get(cls, {}).get("slo_attainment")
        g = static.per_class.get(cls, {}).get("slo_attainment")
        if s is not None:
            m[f"autoscale/{cls}_attainment"] = round(s, 4)
        if cls == "priority":
            ok = (s is not None and g is not None and s >= g)
            m["autoscale/static_priority_attainment"] = \
                round(g, 4) if g is not None else -1.0
    ok = ok and scaled.energy_wh < static.energy_wh \
        and bool(scaled.scale_actions)
    if verbose:
        print(f"\nautoscale vs static @ rate={rate:g}/s: "
              f"energy {scaled.energy_wh:.1f} vs {static.energy_wh:.1f} Wh "
              f"({m['autoscale/energy_saving_x']:.2f}x saving), "
              f"priority attainment "
              f"{m.get('autoscale/priority_attainment')} vs "
              f"{m.get('autoscale/static_priority_attainment')}, "
              f"{len(scaled.scale_actions)} scale actions")
        print(f"autoscaling {'beats' if ok else 'does NOT beat'} the "
              f"static pool on energy at equal priority attainment")
    return m, ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="short horizon (CI bench-smoke mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (e.g. BENCH_serving.json)")
    ap.add_argument("--faults", action="store_true",
                    help="add one sweep point under a default FaultProfile "
                         "(smoke: fault injection on the serving path)")
    ap.add_argument("--profile", nargs="?", const=15, default=None,
                    type=int, metavar="N",
                    help="cProfile the largest sweep point and report the "
                         "top N functions by cumulative time (default 15) "
                         "into the ungated JSON info block")
    ap.add_argument("--min-events-per-s", type=float, default=20_000.0,
                    help="engine-throughput floor asserted on the largest "
                         "sweep point (composite events/s; conservative "
                         "default for shared CI runners — the dev-box "
                         "measurement lands in the JSON info map)")
    args = ap.parse_args()

    if args.fast:
        rates, horizon, warmup = (0.25, 0.75), 2000.0, 200.0
        accept_rate = 0.5
    else:
        # rate 1.0 x 10000s ~ 10k workflows: the headline sweep point
        rates, horizon, warmup = (0.5, 1.0, 1.5), 10000.0, 1000.0
        accept_rate = 0.5

    metrics, info = sweep(rates, horizon, warmup)
    auto_metrics, auto_ok = autoscale_comparison(accept_rate, horizon,
                                                 warmup)
    metrics.update(auto_metrics)
    faults_ok = True
    if args.faults:
        fault_metrics, faults_ok = faults_smoke(max(rates), horizon,
                                                warmup)
        metrics.update(fault_metrics)
    if args.profile:
        info["profile"] = profile_point(max(rates), horizon, warmup,
                                        top_n=args.profile)

    ev_s = info.get("events_per_s", 0)
    print(f"\nengine throughput @ rate={info.get('rate_per_s')}/s: "
          f"{info.get('arrivals')} workflows, "
          f"{info.get('n_events')} events + {info.get('n_attempts')} "
          f"attempts in {info.get('wall_s')}s wall = {ev_s:,} events/s "
          f"(floor {args.min_events_per_s:,.0f})")
    throughput_ok = ev_s >= args.min_events_per_s
    if not throughput_ok:
        print(f"FAIL: {ev_s:,} events/s below the "
              f"{args.min_events_per_s:,.0f} floor")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "serving",
                       "mode": "fast" if args.fast else "full",
                       "info": info, "metrics": metrics},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    return 0 if (throughput_ok and auto_ok and faults_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
