"""Learned-routing benchmark: telemetry loop vs static routing (§11).

Exercises the route -> log -> evaluate -> update loop on the agentic-RAG
workflow over a mixed query set (half *lookup-shaped* — document ids,
fiscal years, tickers, where lexical BM25 retrieval measures above its
declared quality — half *semantic* prose, where BM25 measures below it):

- **static**  — no router; the quality-safe posture an operator runs
  without per-query routing: retrieve floor 0.9 forces the dense route on
  *every* query, because a floor admitting BM25 (declared 0.82) would let
  it serve semantic queries it measurably butchers.
- **explore** — router at epsilon=1.0 under the admitting floor: seeded
  uniform arm picks fill a telemetry store graded by the benchmark's
  ground-truth quality model (the stand-in for an LLM judge).
- **learned** — the ``OfflineEvaluator`` replays the log into per-bucket
  weights; the trained router (epsilon=0) serves the same queries.

Acceptance gates (exit 1 on failure), the ISSUE's headline claims:

1. the learned router matches-or-beats static on **energy AND $ at
   equal-or-better quality attainment** (it learns to send lookup-shaped
   queries to cheap lexical retrieval and semantic ones to dense);
2. quality-aware model selection: calibrating measured quality into the
   ``ProfileStore`` (gemma2-9b-synth measures 0.93 vs its declared 0.90)
   finds a plan **cheaper than the fixed-zoo plan at the same
   quality floor** (0.92 — which on declared qualities only the 104B
   model clears).

CLI::

    PYTHONPATH=src python benchmarks/routing_bench.py              # full
    PYTHONPATH=src python benchmarks/routing_bench.py --fast \\
        --json BENCH_routing.json                                  # CI mode
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import repro.configs.workflow_docingest  # noqa: F401,E402
import repro.configs.workflow_rag  # noqa: F401,E402
import repro.configs.workflow_video  # noqa: F401,E402
from repro.configs.workflow_rag import ROUTED_QUERIES, make_rag_job  # noqa: E402
from repro.core import (Murakkab, OfflineEvaluator, Router,  # noqa: E402
                        TelemetryStore)

SEED = 11
#: attainment target the loop optimizes for (evaluator quality_target)
TARGET = 0.85
#: cost pressure in the bandit reward: small enough that a 0.17 quality
#: gap (BM25 on semantic queries) always outweighs the dense route's
#: higher cost, large enough to prefer BM25 where quality ties
COST_WEIGHT = 0.05
#: the model-selection gate's synthesize floor: on declared qualities only
#: command-r-plus-104b (0.97) clears it; gemma2-9b (declared 0.90,
#: measured 0.93) clears it only after telemetry calibration
SYNTH_FLOOR = 0.92


def quality_model(feats, impl: str, declared: float) -> float:
    """Ground-truth grader stand-in (an LLM judge / labeled evals).

    Encodes the two effects the loop must discover: lexical retrieval
    outperforms its declared quality on lookup-shaped queries and
    underperforms it badly on semantic ones; gemma2-9b-synth measures
    above its declared score. Everything else attains as declared.
    """
    if impl == "bm25-keyword":
        kind = feats.bucket().split(":")[0]
        return 0.95 if kind == "lookup" else 0.70
    if impl == "gemma2-9b-synth":
        return 0.93
    return declared


def _phase(router_for, telemetry: TelemetryStore, floor: dict | None,
           rounds: int = 1) -> tuple[float, float]:
    """Run every routed query ``rounds`` times on one warm-carrying
    system; returns summed (energy_wh, usd). ``router_for(round, qi)``
    supplies the router per job (None = static)."""
    system = Murakkab.paper_cluster(telemetry=telemetry)
    energy = usd = 0.0
    for rd in range(rounds):
        for qi, q in enumerate(ROUTED_QUERIES):
            system.router = router_for(rd, qi)
            res = system.execute(make_rag_job(queries=(q,),
                                              quality_floor=floor))
            energy += res.energy_wh
            usd += res.usd
    return energy, usd


def _attainment(store: TelemetryStore) -> float:
    return store.attainment("retrieve", TARGET)


def _model_selection(explore_log: TelemetryStore, verbose: bool) -> dict:
    """Gate 2: cheaper-than-fixed-zoo plan at the same quality floor."""
    job = make_rag_job(quality_floor={"synthesize": SYNTH_FLOOR})

    fixed = Murakkab.tpu_cluster()
    dag_f, plan_f = fixed.plan(job)
    synth = next(t for t in dag_f.topo_order if "synthesize" in t)

    calib = Murakkab.tpu_cluster()
    pins = OfflineEvaluator(quality_target=TARGET).calibrate_profiles(
        explore_log, calib.profiles, min_count=3)
    dag_c, plan_c = calib.plan(job)

    fixed_usd = plan_f.report(dag_f)["est_usd"]
    calib_usd = plan_c.report(dag_c)["est_usd"]
    out = {
        "fixed_impl": plan_f[synth].impl,
        "calibrated_impl": plan_c[synth].impl,
        "fixed_usd": fixed_usd,
        "calibrated_usd": calib_usd,
        "pins": {k: round(v, 4) for k, v in sorted(pins.items())},
        "floor_met": calib.profiles.quality(plan_c[synth].impl)
        >= SYNTH_FLOOR,
        "cheaper": calib_usd < fixed_usd,
    }
    if verbose:
        print(f"\nmodel selection @ synthesize floor {SYNTH_FLOOR}:")
        print(f"  fixed zoo:  {out['fixed_impl']:>28s}  "
              f"${fixed_usd:.4f}")
        print(f"  calibrated: {out['calibrated_impl']:>28s}  "
              f"${calib_usd:.4f}  "
              f"(pinned q={pins.get(out['calibrated_impl'], 0):.3f})")
    return out


def run(rounds: int, verbose: bool = True) \
        -> tuple[dict[str, float], dict, bool]:
    """(metrics, info, gate_ok) for the routing loop."""
    # static quality-safe baseline: dense retrieval on every query
    static_log = TelemetryStore(quality_model=quality_model)
    s_energy, s_usd = _phase(lambda rd, qi: None, static_log,
                             {"retrieve": 0.9})

    # explore: seeded uniform arm picks fill the telemetry log. The
    # exploration coin is keyed by task identity, and every per-query RAG
    # job names its retrieve task identically — varying the router seed
    # per (round, query) is what spreads the picks across arms.
    # synthesize floor 0.9 makes gemma2-9b (declared 0.90) the arm the
    # explore phase actually runs, so calibration has samples to measure
    # its 0.93 attained quality from
    explore_log = TelemetryStore(quality_model=quality_model)
    _phase(lambda rd, qi: Router(interfaces=("retrieve",), epsilon=1.0,
                                 seed=SEED + 97 * rd + qi),
           explore_log, {"synthesize": 0.9}, rounds=rounds)

    # offline update (pure function of the log), then exploit
    base = Router(interfaces=("retrieve",), epsilon=0.0, seed=SEED)
    evaluator = OfflineEvaluator(quality_target=TARGET,
                                 cost_weight=COST_WEIGHT, cost_key="usd")
    trained = evaluator.update(base, explore_log)
    learned_log = TelemetryStore(quality_model=quality_model)
    l_energy, l_usd = _phase(lambda rd, qi: trained, learned_log, None)

    s_att, l_att = _attainment(static_log), _attainment(learned_log)
    routed = [r for r in learned_log.records if r.routed]
    arms = sorted({(r.features.bucket(), r.impl) for r in routed})

    sel = _model_selection(explore_log, verbose)

    metrics = {
        "static/energy_wh": round(s_energy, 3),
        "static/usd": round(s_usd, 5),
        "static/attainment": round(s_att, 4),
        "learned/energy_wh": round(l_energy, 3),
        "learned/usd": round(l_usd, 5),
        "learned/attainment": round(l_att, 4),
        "learned/weight_churn": trained.weight_churn(base),
        "modelsel/fixed_usd": round(sel["fixed_usd"], 5),
        "modelsel/calibrated_usd": round(sel["calibrated_usd"], 5),
        "modelsel/usd_saving_frac": round(
            1.0 - sel["calibrated_usd"] / max(sel["fixed_usd"], 1e-12), 4),
    }
    info = {
        "rounds": rounds,
        "queries": len(ROUTED_QUERIES),
        "explore_records": len(explore_log),
        "bucket_arms": [f"{b} -> {impl}" for b, impl in arms],
        "model_selection": {k: v for k, v in sel.items()
                            if k not in ("cheaper", "floor_met")},
    }

    gate_route = (l_energy <= s_energy and l_usd <= s_usd
                  and l_att >= s_att)
    gate_model = sel["cheaper"] and sel["floor_met"]
    ok = gate_route and gate_model

    if verbose:
        hdr = (f"{'mode':>8s} {'energy_wh':>10s} {'usd':>9s} "
               f"{'attainment':>11s}")
        print(f"\n{hdr}")
        print("-" * len(hdr))
        print(f"{'static':>8s} {s_energy:>10.3f} {s_usd:>9.5f} "
              f"{s_att:>11.3f}")
        print(f"{'learned':>8s} {l_energy:>10.3f} {l_usd:>9.5f} "
              f"{l_att:>11.3f}")
        print(f"\nlearned routes: {', '.join(info['bucket_arms'])}")
        print(f"gate 1 (routing): energy {l_energy:.3f} <= {s_energy:.3f},"
              f" usd {l_usd:.5f} <= {s_usd:.5f}, attainment {l_att:.3f} >="
              f" {s_att:.3f} => {'PASS' if gate_route else 'FAIL'}")
        print(f"gate 2 (model selection): "
              f"${sel['calibrated_usd']:.4f} < ${sel['fixed_usd']:.4f} "
              f"at floor {SYNTH_FLOOR} "
              f"=> {'PASS' if gate_model else 'FAIL'}")
    return metrics, info, ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="fewer explore rounds (CI bench-smoke mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (e.g. BENCH_routing.json)")
    args = ap.parse_args()

    rounds = 2 if args.fast else 4
    metrics, info, ok = run(rounds)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "routing",
                       "mode": "fast" if args.fast else "full",
                       "info": info, "metrics": metrics},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
