"""Paper §3.3: Murakkab's overheads.

(a) Profiling — amortized: one profile sweep serves every subsequent
    workflow; we measure sweep size/time and per-job reuse.
(b) DAG creation — <1% of workflow execution time (short LLM queries).
(c) Configuration search — greedy hierarchical pruning visits a small
    fraction of the full lever cross-product, even with the joint
    (count x batch) level-2 grid of DESIGN.md §7.2; dominated-config
    pruning (§7.3) cuts the visited count further. Per-plan wall time and
    ``Scheduler.evals`` are reported so planner overhead is tracked next
    to the paper-repro numbers (``--json``; see also planner_bench.py).

CLI::

    PYTHONPATH=src python -m benchmarks.overheads [--json BENCH_overheads.json]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import MIN_COST, Murakkab, dag_creation_overhead
from repro.configs.workflow_video import make_declarative_job

from .paper_eval import prewarm


def run(verbose: bool = True) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []

    # (a) profiling sweep: every (impl x device x count) pair, once
    system = Murakkab.tpu_cluster()
    t0 = time.perf_counter()
    table = system.profiles.profile_table(
        {"tpu-v5e": [1, 8, 64, 256], "tpu-v5p": [8, 64],
         "host-core": [1, 8, 64]})
    sweep_s = time.perf_counter() - t0
    rows.append(("overheads/profile_sweep_entries", len(table), "one-time"))
    rows.append(("overheads/profile_sweep_s", round(sweep_s, 4), "amortized"))

    # (b) DAG creation overhead vs makespan
    system = Murakkab.paper_cluster()
    prewarm(system)
    job = make_declarative_job(MIN_COST)
    res = job.execute(system)
    frac = dag_creation_overhead(res.dag, res.makespan_s)
    rows.append(("overheads/dag_creation_frac", round(frac, 4),
                 "paper <0.01"))

    # (c) greedy search vs full cross-product + per-plan planner overhead
    system = Murakkab.paper_cluster()
    prewarm(system)
    dag = system.lower(job)
    full = sum(system.scheduler.search_space_size(dag.nodes[t])
               for t in dag.topo_order)
    system.scheduler.evals = 0
    t0 = time.perf_counter()
    system.scheduler.plan(dag, job.constraint_order, job.quality_floor)
    plan_wall_ms = (time.perf_counter() - t0) * 1e3
    visited = system.scheduler.evals
    rows.append(("overheads/search_full_space", full, "lever cross-product"))
    rows.append(("overheads/search_visited", visited,
                 "greedy + dominated-config pruning"))
    rows.append(("overheads/search_prune_ratio",
                 round(full / max(visited, 1), 1), "x fewer"))
    rows.append(("overheads/plan_wall_ms", round(plan_wall_ms, 2),
                 "one video-workflow plan"))
    rows.append(("overheads/plan_evals", visited, "estimate() calls/plan"))
    if verbose:
        for r in rows:
            print(f"{r[0]:38s} {r[1]:>12} ({r[2]})")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (wall-time per plan + evals)")
    args = ap.parse_args()
    rows = run(verbose=args.json is not None)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "overheads",
                       "metrics": {name: value for name, value, _ in rows}},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    else:
        for r in rows:
            print(",".join(map(str, r)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
