"""Paper §3.3: Murakkab's overheads.

(a) Profiling — amortized: one profile sweep serves every subsequent
    workflow; we measure sweep size/time and per-job reuse.
(b) DAG creation — <1% of workflow execution time (short LLM queries).
(c) Configuration search — greedy hierarchical pruning visits a small
    fraction of the full lever cross-product.
"""
from __future__ import annotations

import time

from repro.core import MIN_COST, Murakkab, dag_creation_overhead
from repro.configs.workflow_video import make_declarative_job

from .paper_eval import prewarm


def run(verbose: bool = True) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []

    # (a) profiling sweep: every (impl x device x count) pair, once
    system = Murakkab.tpu_cluster()
    t0 = time.perf_counter()
    table = system.profiles.profile_table(
        {"tpu-v5e": [1, 8, 64, 256], "tpu-v5p": [8, 64],
         "host-core": [1, 8, 64]})
    sweep_s = time.perf_counter() - t0
    rows.append(("overheads/profile_sweep_entries", len(table), "one-time"))
    rows.append(("overheads/profile_sweep_s", round(sweep_s, 4), "amortized"))

    # (b) DAG creation overhead vs makespan
    system = Murakkab.paper_cluster()
    prewarm(system)
    job = make_declarative_job(MIN_COST)
    res = job.execute(system)
    frac = dag_creation_overhead(res.dag, res.makespan_s)
    rows.append(("overheads/dag_creation_frac", round(frac, 4),
                 "paper <0.01"))

    # (c) greedy search vs full cross-product
    system = Murakkab.paper_cluster()
    prewarm(system)
    dag = system.lower(job)
    full = sum(system.scheduler.search_space_size(dag.nodes[t])
               for t in dag.topo_order)
    system.scheduler.evals = 0
    system.scheduler.plan(dag, job.constraint_order, job.quality_floor)
    visited = system.scheduler.evals
    rows.append(("overheads/search_full_space", full, "lever cross-product"))
    rows.append(("overheads/search_visited", visited, "greedy"))
    rows.append(("overheads/search_prune_ratio",
                 round(full / max(visited, 1), 1), "x fewer"))
    if verbose:
        for r in rows:
            print(f"{r[0]:38s} {r[1]:>12} ({r[2]})")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
