"""Table 2 reproduction: energy and execution time per STT configuration.

| Speech-to-Text Config. | Energy (Wh) | Time (s) |   <- paper
| Baseline               | 155         | 285      |
| Murakkab CPU           | 34          | 83       |
| Murakkab GPU           | 43          | 77       |
| Murakkab GPU + CPU     | 42          | 77       |

Also verifies the selection claim: MIN_COST picks the CPU configuration
(~4.5x energy efficiency vs baseline).
"""
from __future__ import annotations

from repro.core import MIN_COST, Murakkab
from repro.configs.workflow_video import make_declarative_job

from .paper_eval import PAPER_TARGETS, prewarm, run_all


def run(verbose: bool = True) -> list[tuple[str, float, str]]:
    res = run_all()
    rows: list[tuple[str, float, str]] = []
    if verbose:
        print(f"{'config':<12s} {'Wh':>8s} {'paper':>6s} {'s':>8s} {'paper':>6s}")
    for name, (mk, wh, _) in res.items():
        tm, tw = PAPER_TARGETS[name]
        if verbose:
            print(f"{name:<12s} {wh:8.1f} {tw:6.0f} {mk:8.1f} {tm:6.0f}")
        rows.append((f"table2/{name}/energy_wh", round(wh, 1),
                     f"paper={tw:.0f}"))
        rows.append((f"table2/{name}/time_s", round(mk, 1),
                     f"paper={tm:.0f}"))

    # the selection claim: MIN_COST -> CPU STT
    system = Murakkab.paper_cluster()
    prewarm(system)
    dag, plan = system.plan(make_declarative_job(MIN_COST))
    stt = next(c for t, c in plan.configs.items() if "speech" in t)
    picked_cpu = float(stt.pool == "cpu")
    rows.append(("table2/min_cost_picks_cpu", picked_cpu, "paper=1 (CPU)"))
    eff = res["baseline"][1] / res["cpu"][1]
    rows.append(("table2/energy_efficiency_x", round(eff, 2), "paper~4.5x"))
    if verbose:
        print(f"MIN_COST picks: {stt.impl} on {stt.pool} "
              f"x{stt.n_devices * stt.n_instances}  "
              f"energy-eff {eff:.2f}x")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
