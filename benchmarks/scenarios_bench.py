"""Cross-scenario constraint sweep: the generalization benchmark.

    PYTHONPATH=src python benchmarks/scenarios_bench.py [--fast] [--json PATH]

Runs all three registered scenarios (video, agentic-RAG, doc-ingest) under
each constraint form — seed enum objectives plus the DSL (deadline-gated
energy, weighted cost/energy blend) — on the paper cluster, and prints one
table. The point of the API redesign in one artifact: three workflow shapes,
one planner/scheduler/simulator path, no scenario branches.

``--fast`` restricts to one objective + one DSL constraint per scenario
(the CI ``bench-smoke`` mode); ``--json`` writes the deterministic metrics
(makespan/energy/$/quality — wall-clock planning time excluded) for the
regression gate in ``benchmarks/check_regression.py``.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (Deadline, Lexicographic, MAX_QUALITY, MIN_COST,
                        MIN_ENERGY, MIN_LATENCY, MinEnergy, Murakkab,
                        Weighted)
from repro.configs.workflow_docingest import make_docingest_job
from repro.configs.workflow_rag import make_rag_job
from repro.configs.workflow_video import make_declarative_job

SCENARIOS = [
    ("video", make_declarative_job),
    ("rag", make_rag_job),
    ("docingest", make_docingest_job),
]

CONSTRAINTS = [
    ("MIN_COST", MIN_COST),
    ("MIN_ENERGY", MIN_ENERGY),
    ("MIN_LATENCY", MIN_LATENCY),
    ("MAX_QUALITY", MAX_QUALITY),
    ("DL60s>Energy", Lexicographic(Deadline(s=60.0), MinEnergy())),
    ("W(c=1,e=1e-5)", Weighted.of(cost=1.0, energy=1e-5)),
]

FAST_CONSTRAINTS = [
    ("MIN_COST", MIN_COST),
    ("DL60s>Energy", Lexicographic(Deadline(s=60.0), MinEnergy())),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="one objective + one DSL constraint per scenario")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write metrics JSON (e.g. BENCH_scenarios.json)")
    args = ap.parse_args()
    constraints = FAST_CONSTRAINTS if args.fast else CONSTRAINTS

    metrics: dict[str, float] = {}
    hdr = (f"{'scenario':<10s} {'constraint':<14s} {'makespan_s':>10s} "
           f"{'energy_wh':>9s} {'usd':>8s} {'quality':>7s} "
           f"{'plan_ms':>8s}  chosen impls")
    print(hdr)
    print("-" * len(hdr))
    for sname, make_job in SCENARIOS:
        for cname, c in constraints:
            system = Murakkab.paper_cluster()
            job = make_job(c)
            t0 = time.perf_counter()
            dag, plan = system.plan(job)
            plan_ms = (time.perf_counter() - t0) * 1e3
            result = job.execute(Murakkab.paper_cluster())
            impls = ",".join(plan.configs[t].impl for t in dag.topo_order)
            print(f"{sname:<10s} {cname:<14s} {result.makespan_s:>10.1f} "
                  f"{result.energy_wh:>9.1f} {result.usd:>8.4f} "
                  f"{result.quality:>7.3f} {plan_ms:>8.1f}  {impls}")
            key = f"{sname}/{cname}"
            metrics[f"{key}/makespan_s"] = round(result.makespan_s, 2)
            metrics[f"{key}/energy_wh"] = round(result.energy_wh, 2)
            metrics[f"{key}/usd"] = round(result.usd, 4)
            metrics[f"{key}/quality"] = round(result.quality, 4)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "scenarios",
                       "mode": "fast" if args.fast else "full",
                       "metrics": metrics}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
