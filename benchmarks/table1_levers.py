"""Table 1 reproduction: optimization levers and their impact directions.

For each lever the paper lists the direction of impact on $-cost, power,
latency and quality. We evaluate each lever with the scheduler's own
estimator on the TPU target cluster and assert the published direction.

| Parameter       | Selection       | $Cost  | Power  | Latency          | Quality  |
| GPU generation  | Newer           | Higher | Higher | Lower/No Change  | NoChange |
| CPU vs GPU      | CPU             | Lower  | Lower  | Lower*           | NoChange |
| Task parallelism| More fan out    | Higher | Higher | Lower            | NoChange |
| Execution paths | More paths      | Higher | Higher | Higher/NoChange  | Higher*  |
| Model/tool      | More parameters | Higher | Higher | Higher/NoChange  | Higher*  |

(*) the paper's CPU-latency entry is workload-specific (it is 'Lower' for
their harvested-core scenario because queueing on busy GPUs dominated); for
a dedicated-device comparison CPU latency is higher, so we assert the cost/
power directions, which are the load-bearing ones.
"""
from __future__ import annotations

from repro.core import Murakkab
from repro.core.dag import TaskNode


def _node(items=8, tin=900, tout=120, agent="summarize"):
    return TaskNode(id="t", description="", agent=agent, work_items=items,
                    chunkable=True, tokens_in=tin, tokens_out=tout)


def run(verbose: bool = True) -> list[tuple[str, float, str]]:
    system = Murakkab.tpu_cluster()
    sch = system.scheduler
    rows: list[tuple[str, float, str]] = []
    checks: list[tuple[str, bool, str]] = []

    # --- GPU (chip) generation: v5e -> v5p ------------------------------------
    n = _node()
    impl = system.library.impls["deepseek-7b"]
    old = sch.estimate(n, impl, "v5e", 8)
    new = sch.estimate(n, impl, "v5p", 8)
    checks.append(("gen_newer_cost_higher", new.est_usd > old.est_usd,
                   "Table1 row1 $"))
    checks.append(("gen_newer_power_higher", new.est_power_w > old.est_power_w,
                   "Table1 row1 W"))
    checks.append(("gen_newer_latency_lower_or_eq",
                   new.est_latency_s <= old.est_latency_s * 1.001,
                   "Table1 row1 s"))
    checks.append(("gen_newer_quality_same", new.quality == old.quality,
                   "Table1 row1 q"))

    # --- CPU vs GPU (the paper's own cluster for this row) ----------------------
    paper = Murakkab.paper_cluster()
    stt = _node(agent="speech_to_text", tin=0, tout=0)
    w = paper.library.impls["whisper-large"]
    on_acc = paper.scheduler.estimate(stt, w, "gpu", 1)
    on_cpu = paper.scheduler.estimate(stt, w, "cpu", 64)
    checks.append(("cpu_cost_lower", on_cpu.est_usd < on_acc.est_usd,
                   "Table1 row2 $"))
    checks.append(("cpu_power_lower", on_cpu.est_power_w < on_acc.est_power_w,
                   "Table1 row2 W"))
    checks.append(("cpu_quality_same", on_cpu.quality == on_acc.quality,
                   "Table1 row2 q"))

    # --- Task parallelism (fan-out) -------------------------------------------
    one = sch.estimate(n, impl, "v5e", 8, n_instances=1)
    four = sch.estimate(n, impl, "v5e", 8, n_instances=4)
    checks.append(("fanout_latency_lower", four.est_latency_s < one.est_latency_s,
                   "Table1 row3 s"))
    checks.append(("fanout_quality_same", four.quality == one.quality,
                   "Table1 row3 q"))
    # cost/power: "Higher" in the paper (more devices powered); our marginal
    # model keeps device-seconds ~constant, so assert not-lower:
    checks.append(("fanout_cost_not_lower", four.est_usd >= one.est_usd * 0.999,
                   "Table1 row3 $"))

    # --- Execution paths --------------------------------------------------------
    p1 = sch.estimate(n, impl, "v5e", 8, paths=1)
    p4 = sch.estimate(n, impl, "v5e", 8, paths=4)
    checks.append(("paths_cost_higher", p4.est_usd > p1.est_usd, "Table1 row4 $"))
    checks.append(("paths_power_higher", p4.est_power_w > p1.est_power_w,
                   "Table1 row4 W"))
    checks.append(("paths_quality_higher", p4.quality > p1.quality,
                   "Table1 row4 q"))

    # --- Model/tool (more parameters) -------------------------------------------
    small = sch.estimate(n, system.library.impls["deepseek-7b"], "v5e", 8)
    big = sch.estimate(n, system.library.impls["command-r-plus-104b"],
                       "v5e", 64)
    checks.append(("bigger_model_cost_higher", big.est_usd > small.est_usd,
                   "Table1 row5 $"))
    checks.append(("bigger_model_power_higher",
                   big.est_power_w > small.est_power_w, "Table1 row5 W"))
    checks.append(("bigger_model_quality_higher", big.quality > small.quality,
                   "Table1 row5 q"))

    ok = 0
    for name, passed, note in checks:
        rows.append((f"table1/{name}", float(passed), note))
        ok += passed
        if verbose:
            print(f"{'PASS' if passed else 'FAIL'} {name:34s} ({note})")
    rows.append(("table1/directions_confirmed",
                 round(ok / len(checks), 3), f"{ok}/{len(checks)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
